"""Runtime sanitizer (Environment(sanitize=True), simcore/sanitize.py).

Three injected hazards must be caught — a lock-order inversion, a
same-instant tie, a global-RNG draw — and, just as load-bearing, the
sanitizer must be *invisible*: the event-budget cells from
tests/test_event_budget.py must produce bit-identical pins with sanitize on
and off, because the sanitizer only observes engine hooks and never
schedules, draws, or mutates simulation state.
"""
import random

import numpy as np
import pytest

from repro.simcore import Environment, SanitizeError


def test_sanitize_off_by_default(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert Environment(seed=1).sanitizer is None
    monkeypatch.setenv("REPRO_SANITIZE", "0")
    assert Environment(seed=1).sanitizer is None
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert Environment(seed=1).sanitizer is not None
    # explicit argument beats the environment variable
    assert Environment(seed=1, sanitize=False).sanitizer is None


# -- lock-order cycle detection ----------------------------------------------

def _ab_then_ba(env, a, b):
    """Two processes taking {a, b} in opposite orders — the inversion the
    id-sorted quiesce discipline in control_plane.py exists to prevent."""
    def locker(first, second, delay):
        yield env.timeout(delay)
        yield first.acquire()
        yield env.timeout(0.05)
        yield second.acquire()
        second.release()
        first.release()
    env.process(locker(a, b, 0.0), name="fwd")
    env.process(locker(b, a, 0.01), name="rev")


def test_lock_order_inversion_raises():
    env = Environment(seed=1, sanitize=True)
    a = env.resource(capacity=1, name="lock-a")
    b = env.resource(capacity=1, name="lock-b")
    _ab_then_ba(env, a, b)
    with pytest.raises(SanitizeError, match="lock-order cycle"):
        env.run(until=1.0)
    # the message names both resources and the established chain
    msg = env.sanitizer.lock_cycles[0]
    assert "lock-a" in msg and "lock-b" in msg


def test_lock_order_inversion_silent_without_sanitize():
    # same workload, sanitize off (explicitly, so this holds even under
    # CI's REPRO_SANITIZE=1 sweep): the engine must not care
    env = Environment(seed=1, sanitize=False)
    a = env.resource(capacity=1, name="lock-a")
    b = env.resource(capacity=1, name="lock-b")
    _ab_then_ba(env, a, b)
    env.run(until=1.0)   # no error, no sanitizer
    assert env.sanitizer is None


def test_consistent_lock_order_is_clean():
    env = Environment(seed=1, sanitize=True)
    locks = [env.resource(capacity=1, name=f"lock-{i}") for i in range(3)]

    def sweep(delay):
        yield env.timeout(delay)
        for lk in locks:            # same global order in every process
            yield lk.acquire()
        yield env.timeout(0.02)
        for lk in reversed(locks):
            lk.release()

    for i in range(4):
        env.process(sweep(0.013 * i), name=f"sweeper-{i}")
    env.run(until=2.0)
    rep = env.sanitizer.report()
    assert rep["lock_cycles"] == []
    assert rep["lock_edges"] > 0        # the graph did record the holds


# -- same-instant tie auditing ------------------------------------------------

def test_same_instant_tie_recorded_not_raised():
    env = Environment(seed=1, sanitize=True)
    res = env.resource(capacity=4, name="shared-pool")

    def toucher(i):
        yield env.timeout(0.5)          # both processes arrive at t=0.5
        yield res.acquire()
        yield env.timeout(0.1)
        res.release()

    env.process(toucher(0), name="worker-0")
    env.process(toucher(1), name="worker-1")
    env.run(until=2.0)                  # ties are audited, never fatal
    rep = env.sanitizer.report()
    assert rep["tie_example_count"] > 0
    # digit-normalized pair key: worker-0 vs worker-1 collapse to worker-#
    assert any("shared-pool :: worker-# <> worker-#" == k
               for k in rep["tie_hazards"])


def test_distinct_instants_no_tie():
    env = Environment(seed=1, sanitize=True)
    res = env.resource(capacity=4, name="shared-pool")

    def toucher(delay):
        yield env.timeout(delay)
        yield res.acquire()
        res.release()

    env.process(toucher(0.5), name="worker-0")
    env.process(toucher(0.7), name="worker-1")
    env.run(until=2.0)
    assert env.sanitizer.report()["tie_hazards"] == {}


# -- RNG discipline -----------------------------------------------------------

def _pyrandom_drawer(env):
    yield env.timeout(0.1)
    random.random()                     # the leak


def _np_drawer(env):
    yield env.timeout(0.1)
    np.random.rand()                    # the leak


@pytest.mark.parametrize("leaker", [_pyrandom_drawer, _np_drawer])
def test_global_rng_draw_raises(leaker):
    env = Environment(seed=1, sanitize=True)
    env.process(leaker(env), name="leaker")
    with pytest.raises(SanitizeError, match="global RNG"):
        env.run(until=1.0)
    assert env.sanitizer.rng_violations


def test_named_streams_are_clean():
    env = Environment(seed=1, sanitize=True)

    def drawer():
        rng = env.rng("drawer")
        for _ in range(10):
            yield env.timeout(rng.uniform(0.01, 0.1))
            rng.lognormal(-3.0, 0.5)

    env.process(drawer(), name="drawer")
    env.run(until=5.0)
    assert env.sanitizer.report()["rng_violations"] == []


# -- zero-cost when observing: bit-identical event pins -----------------------

def _budget_cells():
    # importable because pytest puts tests/ on sys.path for sibling modules
    from test_event_budget import run_fixed_cell, run_split_cell
    return run_fixed_cell, run_split_cell


@pytest.mark.parametrize("cell", ["fixed", "split"])
def test_budget_cell_pins_identical_sanitize_on_off(monkeypatch, cell):
    """The acceptance pin: (events_processed, creations, ...) tuples from
    the tier-1 budget cells are byte-identical with REPRO_SANITIZE=1 —
    proof the sanitizer perturbs nothing it observes."""
    run_fixed_cell, run_split_cell = _budget_cells()
    run = run_fixed_cell if cell == "fixed" else run_split_cell
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    off = run()
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    on = run()
    assert on == off
    # the absolute pins, so a change to both paths at once cannot hide
    expected_events = 8_525 if cell == "fixed" else 14_013
    assert off[0] <= expected_events


def test_full_cluster_run_under_sanitize_reports():
    """A real (small) cluster cell runs clean under sanitize and the report
    is inspectable — the shape the CI sanitize step asserts on."""
    from repro.core import Cluster, Function, ScalingConfig

    env = Environment(seed=7, sanitize=True)
    cl = Cluster(env, n_workers=4, runtime="firecracker")
    cl.start()
    cl.register_sync(Function(
        name="f", image_url="i", port=80,
        scaling=ScalingConfig(stable_window=1.0, panic_window=1.0)))
    for _ in range(20):
        cl.invoke("f", exec_time=0.02)
    env.run(until=10.0)
    rep = env.sanitizer.report()
    assert rep["lock_cycles"] == []
    assert rep["rng_violations"] == []
