"""Dirigent abstraction tests: the 16-byte sandbox codec + record round-trips."""
import pytest

from repro.core.abstractions import (
    DataPlaneInfo, Function, Sandbox, SandboxState, ScalingConfig,
    WorkerNodeInfo,
)


def test_sandbox_state_is_16_bytes():
    sb = Sandbox(sandbox_id=123456, function_name="f", ip=(10, 0, 3, 44),
                 port=8443, worker_id=77, state=SandboxState.READY)
    raw = sb.to_bytes()
    assert len(raw) == 16          # the paper's headline number (§3.2)
    back = Sandbox.from_bytes(raw, function_name="f")
    assert back.sandbox_id == 123456
    assert back.ip == (10, 0, 3, 44)
    assert back.port == 8443
    assert back.worker_id == 77
    assert back.state == SandboxState.READY


def test_function_record_roundtrip():
    fn = Function(name="my-func", image_url="registry://img:v3", port=8080,
                  scaling=ScalingConfig(target_concurrency=4.0,
                                        stable_window=30.0, max_scale=99))
    back = Function.from_record(fn.persisted_record())
    assert back.name == fn.name
    assert back.image_url == fn.image_url
    assert back.port == fn.port
    assert back.scaling.target_concurrency == 4.0
    assert back.scaling.stable_window == 30.0
    assert back.scaling.max_scale == 99
    # metrics are NOT persisted (Table 3)
    assert back.metrics.inflight == 0


def test_function_record_excludes_metrics():
    fn = Function(name="f", image_url="i", port=80)
    fn.metrics.inflight = 42
    fn.metrics.total_invocations = 1000
    back = Function.from_record(fn.persisted_record())
    assert back.metrics.inflight == 0
    assert back.metrics.total_invocations == 0


def test_worker_and_dataplane_records():
    w = WorkerNodeInfo(worker_id=3, name="w3", ip=(10, 0, 0, 3), port=9000,
                       cpu_capacity_millis=12000, mem_capacity_mb=32000)
    wb = WorkerNodeInfo.from_record(w.persisted_record())
    assert (wb.worker_id, wb.name, wb.ip, wb.port) == (3, "w3", (10, 0, 0, 3), 9000)
    assert wb.cpu_capacity_millis == 12000

    d = DataPlaneInfo(dp_id=1, ip=(10, 1, 0, 1), port=8080)
    db = DataPlaneInfo.from_record(d.persisted_record())
    assert (db.dp_id, db.ip, db.port) == (1, (10, 1, 0, 1), 8080)


def test_sandbox_record_much_smaller_than_k8s_pod():
    """Paper §3.2: 16 bytes vs ~17 KB K8s Pod objects (3 orders of magnitude)."""
    sb = Sandbox(sandbox_id=1, function_name="f", ip=(1, 2, 3, 4), port=80,
                 worker_id=0)
    assert len(sb.to_bytes()) * 1000 <= 17 * 1024
