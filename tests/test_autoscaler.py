"""KPA autoscaler policy unit tests (shared by Dirigent and the baseline)."""
from repro.core.abstractions import ScalingConfig
from repro.core.autoscaler import ConcurrencyWindow, FunctionAutoscalerState


def make(target=1.0, stable=60.0, panic=6.0, grace=30.0):
    return FunctionAutoscalerState(ScalingConfig(
        target_concurrency=target, stable_window=stable, panic_window=panic,
        scale_to_zero_grace=grace))


def test_window_average_and_eviction():
    w = ConcurrencyWindow(horizon=10.0)
    w.record(0.0, 4.0)
    w.record(5.0, 8.0)
    assert w.average(5.0) == 6.0
    assert w.average(11.0) == 8.0      # first sample evicted
    assert w.average(50.0) == 0.0


def test_scale_up_proportional_to_concurrency():
    st = make(target=2.0)
    st.record_metric(0.0, 10.0)
    assert st.desired(0.0, ready=0) == 5      # ceil(10/2)


def test_panic_mode_entry_and_no_downscale():
    st = make()
    # steady low load
    for t in range(0, 60, 2):
        st.record_metric(float(t), 1.0)
    assert st.desired(60.0, ready=1) == 1
    # sudden burst: panic window avg >> 2x ready
    st.record_metric(61.0, 50.0)
    d = st.desired(61.0, ready=1)
    assert st.in_panic_since is not None
    assert d >= 10           # panic-window avg includes trailing calm samples
    # during panic, never scale below the panic max even if load drops
    st.record_metric(63.0, 0.0)
    assert st.desired(63.0, ready=d) >= d


def test_scale_to_zero_waits_for_grace():
    st = make(stable=10.0, grace=5.0)
    st.record_metric(0.0, 2.0)
    assert st.desired(0.0, 0) == 2
    # load disappears; stable window drains by t=11
    t = 11.0
    st.record_metric(t, 0.0)
    d = st.desired(t, ready=2)
    assert d >= 1            # grace holds one sandbox
    d = st.desired(t + 6.0, ready=1)
    assert d == 0            # grace expired -> scale to zero


def test_recovery_hold_prevents_downscale():
    st = make()
    st.no_downscale_until = 100.0
    st.record_metric(0.0, 0.0)
    assert st.desired(50.0, ready=7) >= 7     # hold active
    assert st.desired(150.0, ready=7) < 7     # hold expired


def test_max_scale_cap():
    st = make()
    st.scaling.max_scale = 3
    st.record_metric(0.0, 100.0)
    assert st.desired(0.0, 0) == 3
