"""Docs-toolchain unit tests: tools/check_markdown_links.py.

The checker is CI's gate for the operator/architecture docs, so its two
validations — relative file targets exist, ``#fragment`` anchors resolve to
real headings (GitHub slug rules) — are pinned here, plus the slugger's
corner cases (code spans, punctuation, duplicate headings).
"""
import importlib.util
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TOOL = os.path.join(ROOT, "tools", "check_markdown_links.py")

spec = importlib.util.spec_from_file_location("check_markdown_links", TOOL)
cml = importlib.util.module_from_spec(spec)
spec.loader.exec_module(cml)


# -- slugger ------------------------------------------------------------------

@pytest.mark.parametrize("heading,slug", [
    ("Quickstart", "quickstart"),
    ("Sharded control plane", "sharded-control-plane"),
    ("Reading `lock_wait_s` / load gauges", "reading-lock_wait_s--load-gauges"),
    ("When to enable rebalancing?", "when-to-enable-rebalancing"),
    ("`BENCH_churn.json`", "bench_churnjson"),
    ("**Bold** and _em_", "bold-and-em"),
    ("C1/C9 (hot shard)", "c1c9-hot-shard"),
])
def test_slugify(heading, slug):
    assert cml.slugify(heading) == slug


def test_duplicate_headings_get_suffixes():
    text = "# Setup\n\n## Setup\n\ntext\n\n## Setup\n"
    assert cml.anchors_of(text) == {"setup", "setup-1", "setup-2"}


def test_headings_inside_code_fences_are_not_anchors():
    text = "# Real\n```bash\n# not a heading\n```\n"
    assert cml.anchors_of(cml._strip_code_fences(text)) == {"real"}


# -- file + anchor checking ---------------------------------------------------

def write(tmp_path, name, content):
    p = tmp_path / name
    p.write_text(content, encoding="utf-8")
    return str(p)


def test_valid_links_and_anchors_pass(tmp_path):
    write(tmp_path, "other.md", "# Target Section\n\nbody\n")
    doc = write(tmp_path, "doc.md", (
        "# Title\n\n## Sub Section\n\n"
        "[in-page](#sub-section) "
        "[file](other.md) "
        "[cross](other.md#target-section) "
        "[web](https://example.com/x#frag)\n"
    ))
    assert cml.check_file(doc) == []


def test_missing_file_reported(tmp_path):
    doc = write(tmp_path, "doc.md", "[gone](nope.md)\n")
    [(path, line, target, reason)] = cml.check_file(doc)
    assert (line, target, reason) == (1, "nope.md", "missing file")


def test_dangling_in_page_anchor_reported(tmp_path):
    doc = write(tmp_path, "doc.md", "# Only\n\n[bad](#nope)\n")
    [(path, line, target, reason)] = cml.check_file(doc)
    assert (line, target, reason) == (3, "#nope", "dangling anchor")


def test_dangling_cross_file_anchor_reported(tmp_path):
    write(tmp_path, "other.md", "# Present\n")
    doc = write(tmp_path, "doc.md", "[bad](other.md#absent)\n")
    [(path, line, target, reason)] = cml.check_file(doc)
    assert (target, reason) == ("other.md#absent", "dangling anchor")


def test_anchor_into_non_markdown_is_ignored(tmp_path):
    write(tmp_path, "data.json", "{}")
    doc = write(tmp_path, "doc.md", "[data](data.json#row-3)\n")
    assert cml.check_file(doc) == []


def test_links_inside_code_fences_are_ignored(tmp_path):
    doc = write(tmp_path, "doc.md",
                "# T\n```md\n[broken](missing.md)\n```\n")
    assert cml.check_file(doc) == []


def test_cli_exit_codes(tmp_path):
    good = write(tmp_path, "good.md", "# A\n[ok](#a)\n")
    bad = write(tmp_path, "bad.md", "[x](#zzz)\n")
    r = subprocess.run([sys.executable, TOOL, good], capture_output=True)
    assert r.returncode == 0, r.stdout
    r = subprocess.run([sys.executable, TOOL, bad], capture_output=True)
    assert r.returncode == 1
    assert b"dangling anchor" in r.stdout


def test_repo_docs_have_no_broken_links_or_anchors():
    """The in-repo docs are themselves the checker's fixture: CI runs this
    same sweep, so keep it green locally too."""
    targets = ["README.md", "ROADMAP.md", "CHANGES.md", "docs"]
    r = subprocess.run([sys.executable, TOOL] + targets,
                       capture_output=True, cwd=ROOT)
    assert r.returncode == 0, r.stdout.decode()
