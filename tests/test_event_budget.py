"""CI event-budget guard: a flake-free perf-regression tripwire.

The simulator is seed-deterministic down to the total number of heap events
it processes (``env.events_processed``), so the cheapest possible perf guard
is an exact event *budget* for a fixed workload: if a change quietly
reintroduces an O(n_workers) background tax (per-worker polling timers,
per-beat sub-processes — the regressions PR 4 removed), the count blows past
the budget long before wall-clock noise could ever detect it, and the test
fails deterministically on any machine.

The budget below was recorded with the PR 4 engine (demand-driven netcfg
refills, per-shard heartbeat wheel, lazy heartbeat lock holds). The same
workload on the pre-PR 4 engine processes ~8x more events, so the guard has
a wide, honest margin. If you *legitimately* reduce event counts further,
tighten the budget; if a feature genuinely needs more events, justify the
new number in the commit that raises it — never raise it to paper over an
accidental regression.
"""
from repro.core import Cluster, Function, ScalingConfig
from repro.simcore import Environment

# exact count recorded for the workload below; see module docstring before
# touching either number
EVENT_BUDGET = 8_525
WORKLOAD = dict(n_workers=50, n_functions=40, waves=5, rate=200.0,
                horizon=16.0, seed=2024)


def run_fixed_cell():
    w = WORKLOAD
    env = Environment(seed=w["seed"])
    cl = Cluster(env, n_workers=w["n_workers"], runtime="firecracker")
    cl.start()
    leader = cl.control_plane_leader()
    names = [f"f{i}" for i in range(w["n_functions"])]
    for n in names:
        leader.install_function(Function(
            name=n, image_url="img://budget", port=80,
            scaling=ScalingConfig(stable_window=1.0, panic_window=1.0,
                                  scale_to_zero_grace=0.2)))
        for dp in cl.data_planes:
            dp.sync_functions([n])

    def driver(env):
        for _ in range(w["waves"]):
            for n in names:
                cl.invoke(n, exec_time=0.05)
                yield env.timeout(1.0 / w["rate"])
            # gap > scale-to-zero grace + autoscale tick: every wave is a
            # full cold scale-up, so the budget covers the whole creation
            # machinery, not just the warm path
            yield env.timeout(2.5)

    env.process(driver(env), name="budget-driver")
    env.run(until=w["horizon"])
    return env.events_processed, cl.collector.sandbox_creations


def test_event_budget_and_determinism():
    events_a, creations_a = run_fixed_cell()
    events_b, creations_b = run_fixed_cell()
    # seed-determinism is what makes an exact budget flake-free: two
    # identical runs must process the identical event sequence
    assert (events_a, creations_a) == (events_b, creations_b)
    assert creations_a > 0, "workload did no real work"
    assert events_a <= EVENT_BUDGET, (
        f"event budget exceeded: {events_a} > {EVENT_BUDGET} — an "
        f"O(n_workers) background tax (idle polling timers, per-beat "
        f"sub-processes) has probably crept back into the hot path")


# -- split-path budget (cp_fn_split_enabled) ----------------------------------
# Exact count for the workload below: a dominant function that triggers the
# full split lifecycle (split handoff, per-slice reconciles/creations on 4
# subshard locks, merge handoff once the heat decays). The split path runs
# extra *work-proportional* events — the handoffs, one reconcile per owning
# subshard per tick — but nothing O(n_workers) or O(sim_time); this pin
# catches a regression that makes split bookkeeping tick when idle, exactly
# like the base pin does for the unsplit path.
SPLIT_EVENT_BUDGET = 14_013
SPLIT_WORKLOAD = dict(n_workers=48, cp_shards=4, n_side_functions=12,
                      waves=4, hot_burst=64, wave_gap=3.0, horizon=16.0,
                      seed=2024)


def run_split_cell():
    w = SPLIT_WORKLOAD
    env = Environment(seed=w["seed"])
    cl = Cluster(env, n_workers=w["n_workers"], runtime="firecracker",
                 cp_shards=w["cp_shards"], cp_rebalance_enabled=True,
                 cp_fn_split_enabled=True)
    cl.start()
    leader = cl.control_plane_leader()
    names = ["hot"] + [f"f{i}" for i in range(w["n_side_functions"])]
    for n in names:
        leader.install_function(Function(
            name=n, image_url="img://budget", port=80,
            scaling=ScalingConfig(stable_window=1.0, panic_window=1.0,
                                  scale_to_zero_grace=0.2)))
        for dp in cl.data_planes:
            dp.sync_functions([n])

    def driver(env):
        for _ in range(w["waves"]):
            # one dominant function carries ~80% of each cold wave
            for _ in range(w["hot_burst"]):
                cl.invoke("hot", exec_time=0.05)
            for n in names[1:]:
                cl.invoke(n, exec_time=0.05)
            yield env.timeout(w["wave_gap"])

    env.process(driver(env), name="split-budget-driver")
    env.run(until=w["horizon"])
    return (env.events_processed, cl.collector.sandbox_creations,
            cl.collector.fn_splits, cl.collector.fn_merges)


def test_split_event_budget_and_determinism():
    a = run_split_cell()
    b = run_split_cell()
    assert a == b, "split path broke seed-determinism"
    events, creations, splits, merges = a
    assert creations > 0, "workload did no real work"
    assert splits >= 1 and merges >= 1, (
        "the workload no longer exercises the full split lifecycle — the "
        "budget would be pinning the wrong path")
    assert events <= SPLIT_EVENT_BUDGET, (
        f"split-path event budget exceeded: {events} > {SPLIT_EVENT_BUDGET} "
        f"— per-slice bookkeeping has probably started costing events when "
        f"idle (see module docstring before touching the budget)")


# -- connection-reuse budget (dp_conn_reuse) ----------------------------------
# Exact count for a warm-heavy workload with the keep-alive connection pool
# on: repeated requests to standing endpoints, short idle timeout so conn
# expiry + TIME_WAIT timers fire inside the horizon. A conn *hit* costs zero
# port events (vs acquire + a 3-event port_hold process per request on the
# no-reuse path) and expiry/TIME_WAIT are single schedule_at callbacks, so
# the same workload with reuse OFF must process strictly MORE events — both
# facts are pinned, so a regression that makes the pool spawn per-request
# processes (or stop hitting) fails deterministically.
REUSE_EVENT_BUDGET = 4_109
REUSE_WORKLOAD = dict(n_workers=24, n_functions=8, waves=6, reqs_per_wave=4,
                      wave_gap=1.0, horizon=14.0, seed=2024)


def run_reuse_cell(conn_reuse: bool):
    w = REUSE_WORKLOAD
    env = Environment(seed=w["seed"])
    cl = Cluster(env, n_workers=w["n_workers"], runtime="firecracker",
                 dp_conn_reuse=conn_reuse, dp_conn_idle_timeout=2.0)
    cl.start()
    leader = cl.control_plane_leader()
    names = [f"f{i}" for i in range(w["n_functions"])]
    for n in names:
        leader.install_function(Function(
            name=n, image_url="img://budget", port=80,
            scaling=ScalingConfig(stable_window=300.0,
                                  scale_to_zero_grace=300.0)))
        for dp in cl.data_planes:
            dp.sync_functions([n])

    def driver(env):
        for _ in range(w["waves"]):
            # gap < idle timeout: wave k+1 reuses wave k's parked conns;
            # the final waves' conns idle out inside the horizon
            for n in names:
                for _ in range(w["reqs_per_wave"]):
                    cl.invoke(n, exec_time=0.02)
            yield env.timeout(w["wave_gap"])

    env.process(driver(env), name="reuse-budget-driver")
    env.run(until=w["horizon"])
    hits = sum(dp.conn_hits for dp in cl.data_planes)
    expired = sum(dp.conn_expired for dp in cl.data_planes)
    done = len(cl.collector.completed)
    return env.events_processed, hits, expired, done


def test_conn_reuse_event_budget_and_determinism():
    a = run_reuse_cell(conn_reuse=True)
    b = run_reuse_cell(conn_reuse=True)
    assert a == b, "conn-reuse path broke seed-determinism"
    events, hits, expired, done = a
    assert done > 0, "workload did no real work"
    assert hits > 0 and expired > 0, (
        "the workload no longer exercises conn reuse + idle expiry — the "
        "budget would be pinning the wrong path")
    events_off, hits_off, _, done_off = run_reuse_cell(conn_reuse=False)
    assert hits_off == 0 and done_off == done
    assert events < events_off, (
        "connection reuse stopped saving events — a hit should cost zero "
        "port events vs acquire + port_hold per request")
    assert events <= REUSE_EVENT_BUDGET, (
        f"conn-reuse event budget exceeded: {events} > {REUSE_EVENT_BUDGET} "
        f"— the keep-alive pool has probably started paying per-request "
        f"events (see module docstring before touching the budget)")


# -- recovery budget (incremental leader failover) ----------------------------
# Exact count for a fixed failover workload: standing traffic on a 4-shard
# CP, leader killed mid-run, incremental per-shard recovery replays the
# snapshot and re-admits traffic shard by shard. The replay itself is
# work-proportional — O(functions + overrides + workers) timeouts costed at
# ``cp_cross_shard_op`` — so the budget catches a recovery path that starts
# paying per-sandbox or per-heartbeat events during replay. The
# ``cp-shard-recovered`` count doubles as proof the *incremental* path (not
# the serial fallback) is the one being pinned.
RECOVERY_EVENT_BUDGET = 14_931
RECOVERY_WORKLOAD = dict(n_workers=32, cp_shards=4, n_functions=16,
                         kill_at=6.0, horizon=14.0, seed=2024)


def run_recovery_cell():
    w = RECOVERY_WORKLOAD
    env = Environment(seed=w["seed"])
    cl = Cluster(env, n_workers=w["n_workers"], runtime="firecracker",
                 cp_shards=w["cp_shards"], enable_ha_sim=True)
    cl.start()
    leader = cl.control_plane_leader()
    names = [f"f{i}" for i in range(w["n_functions"])]
    for n in names:
        leader.install_function(Function(
            name=n, image_url="img://budget", port=80,
            scaling=ScalingConfig(stable_window=30.0,
                                  scale_to_zero_grace=30.0)))
        for dp in cl.data_planes:
            dp.sync_functions([n])

    def driver(env):
        while True:
            for n in names:
                cl.invoke(n, exec_time=0.05)
            yield env.timeout(0.5)

    env.process(driver(env), name="recovery-budget-driver")
    env.run(until=w["kill_at"])
    cl.fail_control_plane_leader()
    env.run(until=w["horizon"])
    shard_recoveries = len(
        cl.collector.event_times("cp-shard-recovered", after=w["kill_at"]))
    recovered = cl.collector.first_event_at("cp-recovered",
                                            after=w["kill_at"])
    return (env.events_processed, cl.collector.sandbox_creations,
            shard_recoveries, recovered)


def test_recovery_event_budget_and_determinism():
    a = run_recovery_cell()
    b = run_recovery_cell()
    assert a == b, "failover recovery broke seed-determinism"
    events, creations, shard_recoveries, recovered = a
    assert creations > 0, "workload did no real work"
    assert shard_recoveries == RECOVERY_WORKLOAD["cp_shards"], (
        "the incremental per-shard recovery path did not engage — the "
        "budget would be pinning the serial fallback")
    assert recovered is not None, "new leader never finished recovery"
    assert events <= RECOVERY_EVENT_BUDGET, (
        f"recovery event budget exceeded: {events} > {RECOVERY_EVENT_BUDGET} "
        f"— replay has probably started paying per-sandbox or O(n_workers) "
        f"events (see module docstring before touching the budget)")


# -- group-commit budget (persist_group_commit) -------------------------------
# Exact count for a bursty workload with the durability ablation ON
# (``persist_sandbox_state``: every creation/teardown pays a store write) and
# group commit ON: concurrent cold-start writes queue behind the in-flight
# fsync and are absorbed into batches, so the WAL pays one fsync + one
# replication round per BATCH instead of per write. Group commit's win is
# serialized fsync sim-TIME (test_persistence pins the >=5x boot cut), not
# raw event count — a grouped write still costs its completion event — so
# this pin guards the ON path's event complexity directly: exact budget,
# two-run determinism, and the batch counters proving absorption actually
# engaged (otherwise the budget would pin a degenerate one-write-per-batch
# path). The off-path pins above already guarantee ``group_commit=False``
# stays bit-identical to the pre-feature store.
GROUP_COMMIT_EVENT_BUDGET = 9_348
GC_WORKLOAD = dict(n_workers=50, n_functions=40, waves=5, wave_gap=2.5,
                   horizon=16.0, seed=2024)


def run_group_commit_cell():
    w = GC_WORKLOAD
    env = Environment(seed=w["seed"])
    cl = Cluster(env, n_workers=w["n_workers"], runtime="firecracker",
                 persist_sandbox_state=True, persist_group_commit=True)
    cl.start()
    leader = cl.control_plane_leader()
    names = [f"f{i}" for i in range(w["n_functions"])]
    for n in names:
        leader.install_function(Function(
            name=n, image_url="img://budget", port=80,
            scaling=ScalingConfig(stable_window=1.0, panic_window=1.0,
                                  scale_to_zero_grace=0.2)))
        for dp in cl.data_planes:
            dp.sync_functions([n])

    def driver(env):
        for _ in range(w["waves"]):
            # a simultaneous cold burst: ~n_functions creations race, their
            # sandbox writes queue behind one in-flight fsync and absorb
            # into large batches — the regime group commit exists for
            for n in names:
                cl.invoke(n, exec_time=0.05)
            yield env.timeout(w["wave_gap"])

    env.process(driver(env), name="gc-budget-driver")
    env.run(until=w["horizon"])
    return (env.events_processed, cl.collector.sandbox_creations,
            cl.store.group_commits, cl.store.group_commit_writes)


def test_group_commit_event_budget_and_determinism():
    a = run_group_commit_cell()
    b = run_group_commit_cell()
    assert a == b, "group commit broke seed-determinism"
    events, creations, commits, commit_writes = a
    assert creations > 0, "workload did no real work"
    assert commits > 0 and commit_writes > commits, (
        "no batch ever absorbed more than one writer — the workload no "
        "longer contends on the WAL and the budget would pin nothing")
    assert events <= GROUP_COMMIT_EVENT_BUDGET, (
        f"group-commit event budget exceeded: {events} > "
        f"{GROUP_COMMIT_EVENT_BUDGET} — the committer has probably started "
        f"paying per-member events (see module docstring before touching "
        f"the budget)")
