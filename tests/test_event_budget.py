"""CI event-budget guard: a flake-free perf-regression tripwire.

The simulator is seed-deterministic down to the total number of heap events
it processes (``env.events_processed``), so the cheapest possible perf guard
is an exact event *budget* for a fixed workload: if a change quietly
reintroduces an O(n_workers) background tax (per-worker polling timers,
per-beat sub-processes — the regressions PR 4 removed), the count blows past
the budget long before wall-clock noise could ever detect it, and the test
fails deterministically on any machine.

The budget below was recorded with the PR 4 engine (demand-driven netcfg
refills, per-shard heartbeat wheel, lazy heartbeat lock holds). The same
workload on the pre-PR 4 engine processes ~8x more events, so the guard has
a wide, honest margin. If you *legitimately* reduce event counts further,
tighten the budget; if a feature genuinely needs more events, justify the
new number in the commit that raises it — never raise it to paper over an
accidental regression.
"""
from repro.core import Cluster, Function, ScalingConfig
from repro.simcore import Environment

# exact count recorded for the workload below; see module docstring before
# touching either number
EVENT_BUDGET = 8_525
WORKLOAD = dict(n_workers=50, n_functions=40, waves=5, rate=200.0,
                horizon=16.0, seed=2024)


def run_fixed_cell():
    w = WORKLOAD
    env = Environment(seed=w["seed"])
    cl = Cluster(env, n_workers=w["n_workers"], runtime="firecracker")
    cl.start()
    leader = cl.control_plane_leader()
    names = [f"f{i}" for i in range(w["n_functions"])]
    for n in names:
        leader.install_function(Function(
            name=n, image_url="img://budget", port=80,
            scaling=ScalingConfig(stable_window=1.0, panic_window=1.0,
                                  scale_to_zero_grace=0.2)))
        for dp in cl.data_planes:
            dp.sync_functions([n])

    def driver(env):
        for _ in range(w["waves"]):
            for n in names:
                cl.invoke(n, exec_time=0.05)
                yield env.timeout(1.0 / w["rate"])
            # gap > scale-to-zero grace + autoscale tick: every wave is a
            # full cold scale-up, so the budget covers the whole creation
            # machinery, not just the warm path
            yield env.timeout(2.5)

    env.process(driver(env), name="budget-driver")
    env.run(until=w["horizon"])
    return env.events_processed, cl.collector.sandbox_creations


def test_event_budget_and_determinism():
    events_a, creations_a = run_fixed_cell()
    events_b, creations_b = run_fixed_cell()
    # seed-determinism is what makes an exact budget flake-free: two
    # identical runs must process the identical event sequence
    assert (events_a, creations_a) == (events_b, creations_b)
    assert creations_a > 0, "workload did no real work"
    assert events_a <= EVENT_BUDGET, (
        f"event budget exceeded: {events_a} > {EVENT_BUDGET} — an "
        f"O(n_workers) background tax (idle polling timers, per-beat "
        f"sub-processes) has probably crept back into the hot path")
