"""Per-function creation sharding: fn→shard-set ownership
(core/control_plane.py, ``cp_fn_split_enabled``).

Claims pinned here:

1. With split off (the default) nothing changes — table entries stay plain
   ints and no split machinery runs (the bit-identity itself is pinned by
   the goldens in tests/test_cp_sharding.py and tests/test_event_budget.py).

2. A single dominant function — one no whole-function migration can fix —
   is split across a shard-set: the indirection-table entry becomes a tuple
   (home subshard first), every member shard owns a ``FunctionSlice``, new
   creations run under the subshards' own scale locks on their own worker
   partitions, and the hot shard's lock convoy measurably shrinks at equal
   shard count while total creations stay equal.

3. The split→merge round trip leaves the table, the shard maps, the global
   ``FunctionState`` and the persisted ``shardmap/`` overrides consistent.

4. ``recover_as_leader`` replays shard-set overrides so failover keeps the
   split; recovered sandboxes are adopted into slices.

5. Endpoint-flush entries pending on subshard queues during a merge handoff
   travel to the surviving queue and are delivered exactly once.

6. A deposed leader's in-flight split (or merge) aborts without touching
   shared state.
"""
import pytest

from repro.core import Cluster, Function, Sandbox, ScalingConfig
from repro.core.autoscaler import split_shares
from repro.simcore import Environment, stable_hash

COLD_SCALING = dict(stable_window=1.0, panic_window=1.0,
                    scale_to_zero_grace=0.2, cpu_req_millis=100,
                    mem_req_mb=128)
# for tests that assert on sandbox sets across handoffs: nothing scales to
# zero (or down) behind the assertions
LONG_SCALING = dict(stable_window=300, scale_to_zero_grace=300,
                    cpu_req_millis=100, mem_req_mb=128)


def make_cluster(seed=5, **kw):
    env = Environment(seed=seed)
    kw.setdefault("n_workers", 64)
    kw.setdefault("runtime", "firecracker")
    kw.setdefault("cp_shards", 4)
    cl = Cluster(env, **kw)
    cl.start()
    return env, cl


def preload(cl, names, scaling_kw=COLD_SCALING):
    leader = cl.control_plane_leader()
    for name in names:
        fn = Function(name=name, image_url="img://bench", port=80,
                      scaling=ScalingConfig(**scaling_kw))
        leader.install_function(fn)
        for dp in cl.data_planes:
            dp.sync_functions([name])
    return leader


def drive_dominant(env, cl, hot="hot", side=(), hot_burst=120, until=24.0,
                   period=4.0):
    """Unison cold bursts where ``hot`` carries ~the whole creation load."""
    def bursts(env):
        while env.now < until:
            for _ in range(hot_burst):
                cl.invoke(hot, exec_time=0.05)
            for n in side:
                cl.invoke(n, exec_time=0.05)
            yield env.timeout(period)
    env.process(bursts(env), name="bursts")


def assert_ownership_consistent(leader):
    """Table ↔ shard maps ↔ slices all agree, for every function."""
    owned = {}
    for shard in leader.shards:
        for n in shard.functions:
            owned.setdefault(n, []).append(shard.shard_id)
    for n, st in leader.functions.items():
        ids = leader._fn_shard_ids(n)
        assert sorted(owned.get(n, [])) == sorted(ids), \
            f"{n}: shard maps {owned.get(n)} vs table {ids}"
        if st.slices is None:
            assert len(ids) == 1
        else:
            assert set(st.slices) == set(ids)
            assert len(ids) >= 2
            # every slice-owned sandbox exists globally; no sandbox is
            # owned by two slices
            seen = set()
            for sl in st.slices.values():
                assert sl.sandbox_ids <= set(st.sandboxes)
                assert not (sl.sandbox_ids & seen)
                seen |= sl.sandbox_ids


# -- the share function -------------------------------------------------------

def test_split_shares_round_robin_residual():
    # shares always sum to desired, spread base+0/1, and the residual
    # rotates deterministically with the cursor
    for desired in range(0, 17):
        for k in (2, 3, 4, 8):
            for cursor in range(k):
                shares = split_shares(desired, k, cursor)
                assert sum(shares) == desired
                assert max(shares) - min(shares) <= 1
    # cursor semantics: positions (cursor + i) % k carry the residual
    assert split_shares(5, 4, 0) == [2, 1, 1, 1]
    assert split_shares(5, 4, 1) == [1, 2, 1, 1]
    assert split_shares(5, 4, 3) == [1, 1, 1, 2]
    assert split_shares(6, 4, 3) == [2, 1, 1, 2]


# -- default off: inert -------------------------------------------------------

def test_split_disabled_table_stays_ints():
    env, cl = make_cluster(cp_rebalance_enabled=True)   # split NOT enabled
    leader = preload(cl, ["hot"] + [f"s{i}" for i in range(6)])
    drive_dominant(env, cl, side=[f"s{i}" for i in range(6)], until=12.0)
    env.run(until=16.0)
    assert cl.collector.fn_splits == 0
    assert all(type(v) is int for v in leader.fn_shard_table.values())
    assert all(st.slices is None for st in leader.functions.values())


# -- split end to end ---------------------------------------------------------

def hot_fn_cell(split: bool, seed=5):
    env, cl = make_cluster(seed=seed, cp_rebalance_enabled=True,
                           cp_fn_split_enabled=split)
    side = [f"s{i}" for i in range(8)]
    leader = preload(cl, ["hot"] + side)
    drive_dominant(env, cl, side=side, until=24.0)
    env.run(until=28.0)
    lock_waits = sorted((s.lock_wait_s for s in leader.shards), reverse=True)
    return env, cl, leader, lock_waits


def test_dominant_fn_splits_and_spreads_the_convoy():
    env, cl, leader, waits_on = hot_fn_cell(split=True)
    assert cl.collector.fn_splits >= 1
    assert all(not i.failed for i in cl.collector.invocations)
    assert_ownership_consistent(leader)
    # same workload without split: the dominant function convoys one lock
    env0, cl0, leader0, waits_off = hot_fn_cell(split=False)
    assert all(not i.failed for i in cl0.collector.invocations)
    # equal shard count, hot-shard lock wait at least halved, same work
    assert waits_on[0] < waits_off[0] / 2, \
        f"split did not relieve the convoy: {waits_off[0]} -> {waits_on[0]}"
    assert (cl.collector.sandbox_creations
            == cl0.collector.sandbox_creations), "split changed the work"


def test_split_creations_use_subshard_locks_and_partitions():
    """While split, each subshard creates on its own worker partition — the
    replicas of one function land across multiple partitions, and multiple
    subshard locks accumulate wait from its bursts."""
    env, cl = make_cluster(cp_rebalance_enabled=True, cp_fn_split_enabled=True,
                           cp_fn_split_cooldown=60.0)   # hold the split
    leader = preload(cl, ["hot"])
    drive_dominant(env, cl, until=21.0)
    env.run(until=19.5)        # mid-burst-cycle, shortly after a wave
    st = leader.functions["hot"]
    assert st.slices is not None, "dominant function never split"
    members = leader._fn_shard_ids("hot")
    assert leader.fn_shard_table["hot"] == members
    assert members[0] == stable_hash("hot") % 4     # home first
    parts = {sb.worker_id % 4 for sb in st.sandboxes.values()}
    assert len(parts) >= 2, f"replicas stayed on one partition: {parts}"
    assert parts <= set(members)    # shard-local placement per subshard
    busy = [s.shard_id for s in leader.shards if s.lock_wait_s > 0.0]
    assert len(set(busy) & set(members)) >= 2, \
        f"creation load did not spread over subshard locks: {busy}"


# -- split ↔ merge round trip --------------------------------------------------

def test_split_merge_round_trip_consistent():
    # park the automatic escalation (huge tick) — this test drives the
    # handoffs directly for determinism
    env, cl = make_cluster(cp_fn_split_enabled=True, cp_rebalance_period=1e9)
    leader = preload(cl, ["f"], scaling_kw=LONG_SCALING)
    invs = [cl.invoke("f", exec_time=30.0) for _ in range(4)]
    env.run(until=5.0)
    assert all(not i.failed for i in invs)
    st = leader.functions["f"]
    n_before = set(st.sandboxes)
    assert len(n_before) >= 2
    home = leader._fn_shard_id("f")
    others = [k for k in range(4) if k != home]
    members = (home, others[0], others[1])
    ev = env.process(leader._split_function("f", members), name="split")
    env.run_until_event(ev)
    assert cl.collector.fn_splits == 1
    assert leader.fn_shard_table["f"] == members
    assert st.slices is not None and set(st.slices) == set(members)
    # existing sandboxes were partitioned round-robin across the set
    assert set().union(*(sl.sandbox_ids for sl in st.slices.values())) \
        == n_before
    assert_ownership_consistent(leader)
    env.run(until=env.now + 1.0)
    # durable shard-set override
    rec = cl.store.peek_prefix("shardmap/")["shardmap/f"]
    assert tuple(int(x) for x in rec.decode().split(",")) == members

    ev = env.process(leader._merge_function("f"), name="merge")
    env.run_until_event(ev)
    env.run(until=env.now + 1.0)
    assert cl.collector.fn_merges == 1
    assert leader.fn_shard_table["f"] == home        # back to a plain int
    assert st.slices is None
    assert set(st.sandboxes) == n_before             # nothing lost
    assert st.creating == 0
    assert_ownership_consistent(leader)
    # override either tombstoned (home is the hash default) or pointing home
    shardmap = cl.store.peek_prefix("shardmap/")
    if home == stable_hash("f") % 4:
        assert "shardmap/f" not in shardmap
    else:
        assert int(shardmap["shardmap/f"].decode()) == home
    # the function still scales: new work after the round trip succeeds
    late = [cl.invoke("f", exec_time=0.01) for _ in range(3)]
    env.run(until=env.now + 10.0)
    assert all(not i.failed for i in late)


def test_scale_to_zero_sees_global_count_and_merge_follows():
    """A split function's slices all drain to zero (one coherent global
    desired count drives every slice), then the merge escalation folds it
    back automatically."""
    env, cl = make_cluster(cp_rebalance_enabled=True, cp_fn_split_enabled=True,
                           cp_fn_split_cooldown=3.0)
    leader = preload(cl, ["hot"])
    drive_dominant(env, cl, until=13.0)
    env.run(until=12.0)
    st = leader.functions["hot"]
    assert st.slices is not None, "dominant function never split"
    # traffic stops; grace 0.2 s + autoscale ticks drain every slice
    env.run(until=40.0)
    assert st.ready_count == 0 and st.creating == 0
    assert st.slices is None, "cooled-down split never merged back"
    assert cl.collector.fn_merges >= 1
    assert type(leader.fn_shard_table["hot"]) is int
    assert_ownership_consistent(leader)


# -- failover -----------------------------------------------------------------

def test_failover_replays_shard_set_override():
    env, cl = make_cluster(cp_fn_split_enabled=True, enable_ha_sim=True,
                           n_workers=16, cp_rebalance_period=1e9)
    leader = cl.control_plane_leader()
    for n in ("f", "g"):
        # real registration: failover rebuilds from the persisted records
        cl.register_sync(Function(name=n, image_url="img://bench", port=80,
                                  scaling=ScalingConfig(**LONG_SCALING)))
    invs = [cl.invoke("f", exec_time=30.0) for _ in range(4)]
    env.run(until=5.0)
    assert all(not i.failed for i in invs)
    home = leader._fn_shard_id("f")
    members = (home, (home + 1) % 4, (home + 3) % 4)
    ev = env.process(leader._split_function("f", members), name="split")
    env.run_until_event(ev)
    env.run(until=env.now + 1.0)     # let the override persist
    n_sandboxes = len(leader.functions["f"].sandboxes)
    assert n_sandboxes >= 1
    cl.fail_control_plane_leader()
    env.run(until=env.now + 3.0)
    new_leader = cl.control_plane_leader()
    assert new_leader is not None and new_leader is not leader
    st = new_leader.functions["f"]
    assert new_leader.fn_shard_table["f"] == members
    assert st.slices is not None and set(st.slices) == set(members)
    assert_ownership_consistent(new_leader)
    # sandbox state came back from the workers and was adopted into slices
    assert len(st.sandboxes) == n_sandboxes
    assert set().union(*(sl.sandbox_ids for sl in st.slices.values())) \
        == set(st.sandboxes)
    # the split function (and its unsplit sibling) still serve traffic
    late = [cl.invoke(n, exec_time=0.01) for n in ("f", "g")]
    env.run(until=env.now + 10.0)
    assert all(not i.failed for i in late)


# -- exactly-once endpoint flush ----------------------------------------------

def test_merge_handoff_moves_pending_ep_flush_entries_exactly_once():
    """Endpoint updates pending on several subshard queues when the merge
    handoff runs must move to the surviving queue and reach every DP exactly
    once — never dropped, never double-broadcast."""
    env, cl = make_cluster(cp_fn_split_enabled=True, n_workers=8,
                           cp_rebalance_period=1e9)
    leader = preload(cl, ["f"])
    home = leader._fn_shard_id("f")
    members = (home, (home + 1) % 4)
    ev = env.process(leader._split_function("f", members), name="split")
    env.run_until_event(ev)
    st = leader.functions["f"]
    adds = []
    for dp in cl.data_planes:
        orig = dp.add_endpoint

        def spy(fn, sandbox, _orig=orig, _dp=dp):
            adds.append((_dp.dp_id, sandbox.sandbox_id))
            _orig(fn, sandbox)
        dp.add_endpoint = spy
    # one pending add per subshard queue, then merge in the same event-loop
    # turn: the handoff (in-memory hops) wins the race against the batched
    # flush (a gRPC), so the entries must travel with the merge
    for i, k in enumerate(members):
        sb = Sandbox(sandbox_id=901 + i, function_name="f",
                     ip=(10, 0, 0, 1 + i), port=80, worker_id=k)
        st.sandboxes[sb.sandbox_id] = sb
        st.slices[k].sandbox_ids.add(sb.sandbox_id)
        leader._queue_endpoint_update("add", "f", sb,
                                      shard=leader.shards[k])
        assert any(u[1] == "f" for u in leader.shards[k].ep_updates)
    ev = env.process(leader._merge_function("f"), name="merge")
    env.run_until_event(ev)
    assert not any(u[1] == "f"
                   for u in leader.shards[members[1]].ep_updates), \
        "pending entry left behind on a dissolved subshard queue"
    env.run(until=env.now + 1.0)
    for dp in cl.data_planes:
        assert sorted(dp.tables["f"].endpoints) == [901, 902]
    for dp_id in range(len(cl.data_planes)):
        for sid in (901, 902):
            n = adds.count((dp_id, sid))
            assert n == 1, f"dp{dp_id} saw endpoint {sid} {n} times"


# -- deposed leader -----------------------------------------------------------

@pytest.mark.parametrize("handoff", ["split", "merge"])
def test_deposed_leader_split_handoff_aborts(handoff):
    env, cl = make_cluster(cp_fn_split_enabled=True, n_workers=8,
                           n_control_planes=1, cp_rebalance_period=1e9)
    leader = preload(cl, ["f"])
    home = leader._fn_shard_id("f")
    members = (home, (home + 1) % 4)
    if handoff == "merge":
        ev = env.process(leader._split_function("f", members), name="split")
        env.run_until_event(ev)
        env.run(until=env.now + 1.0)
    table_before = dict(leader.fn_shard_table)
    store_before = dict(cl.store.peek_prefix("shardmap/"))
    splits_before = cl.collector.fn_splits
    merges_before = cl.collector.fn_merges
    proc = (leader._split_function("f", members) if handoff == "split"
            else leader._merge_function("f"))
    env.process(proc, name=handoff)
    leader.stop()
    env.run(until=env.now + 2.0)
    assert cl.collector.fn_splits == splits_before
    assert cl.collector.fn_merges == merges_before
    assert leader.fn_shard_table == table_before
    assert dict(cl.store.peek_prefix("shardmap/")) == store_before
    if handoff == "split":
        assert leader.functions["f"].slices is None
        assert "f" not in leader.shards[members[1]].functions
    else:
        assert leader.functions["f"].slices is not None


def test_split_during_inflight_creations_no_double_ownership():
    """Regression: a sandbox still CREATING when the split handoff runs is
    partitioned into a slice at split time; when it turns READY the
    sole-owner creation path must not adopt it into a *second* slice."""
    env, cl = make_cluster(cp_fn_split_enabled=True, cp_rebalance_period=1e9)
    leader = preload(cl, ["f"], scaling_kw=LONG_SCALING)
    # queue 6 invocations and split while their sandboxes are mid-boot
    # (firecracker restore ~40 ms; split at ~5 ms is inside every boot)
    invs = [cl.invoke("f", exec_time=30.0) for _ in range(6)]
    env.run(until=env.now + 0.005)
    st = leader.functions["f"]
    assert st.creating > 0, "no creation in flight — test lost its race"
    home = leader._fn_shard_id("f")
    members = (home, (home + 1) % 4, (home + 2) % 4)
    ev = env.process(leader._split_function("f", members), name="split")
    env.run_until_event(ev)
    env.run(until=10.0)
    assert all(not i.failed for i in invs)
    assert st.ready_count >= 6
    assert_ownership_consistent(leader)        # no sandbox owned twice
    assert (sum(st.slice_ready(sl) for sl in st.slices.values())
            == st.ready_count)


def test_reinstall_of_split_function_collapses_to_home():
    """Regression: install_function on a name whose table entry is a
    shard-set (spec re-registration of a live split function) must not
    crash — the fresh unsplit state collapses back to the home shard."""
    env, cl = make_cluster(cp_fn_split_enabled=True, n_workers=8,
                           cp_rebalance_period=1e9)
    leader = preload(cl, ["f"])
    home = leader._fn_shard_id("f")
    members = (home, (home + 1) % 4)
    ev = env.process(leader._split_function("f", members), name="split")
    env.run_until_event(ev)
    fn2 = Function(name="f", image_url="img://v2", port=80,
                   scaling=ScalingConfig(**COLD_SCALING))
    st2 = leader.install_function(fn2)
    assert leader.functions["f"] is st2
    assert leader.fn_shard_table["f"] == home
    assert st2.slices is None
    assert "f" not in leader.shards[members[1]].functions
    assert_ownership_consistent(leader)
    inv = cl.invoke("f", exec_time=0.01)
    env.run(until=env.now + 10.0)
    assert not inv.failed


def test_failover_replay_seeds_split_cooldown():
    """Regression: a replayed shard-set starts with zero slice heat; without
    the seeded cooldown, the new leader's first rebalance tick would merge
    the split right back — failover must keep splits with hysteresis (and
    the merge machinery must still work on the new leader afterwards)."""
    env, cl = make_cluster(cp_fn_split_enabled=True, enable_ha_sim=True,
                           n_workers=32)    # rebalance loop at default period
    leader = cl.control_plane_leader()
    cl.register_sync(Function(name="hot", image_url="i", port=80,
                              scaling=ScalingConfig(**COLD_SCALING)))
    drive_dominant(env, cl, until=12.0)
    env.run(until=11.0)
    assert leader.functions["hot"].slices is not None, \
        "dominant function never split before the failover"
    members = leader.fn_shard_table["hot"]
    merges_before = cl.collector.fn_merges
    cl.fail_control_plane_leader()
    t_fail = env.now
    # several rebalance ticks on the new leader, traffic gone, heat ~zero:
    # only the seeded cooldown keeps the replayed split alive
    env.run(until=t_fail + 6.0)
    new_leader = cl.control_plane_leader()
    assert new_leader is not leader
    st = new_leader.functions["hot"]
    assert new_leader.fn_shard_table["hot"] == members
    assert st.slices is not None, \
        "replayed split merged back on the first rebalance tick"
    assert st.split_cooldown_until > t_fail
    assert cl.collector.fn_merges == merges_before
    # ...and once the cooldown elapses with the function cold, the new
    # leader's own merge escalation folds it home
    env.run(until=t_fail + 30.0)
    assert st.slices is None
    assert cl.collector.fn_merges == merges_before + 1


def test_merge_during_split_scale_down_reconcile():
    """Regression: a global reconcile tearing a split function down yields
    per victim (channel op / persisted delete); a merge handoff completing
    inside such a yield dissolves the slices — the reconcile must bail out
    instead of dereferencing them (pre-fix: AttributeError escapes the
    process and the scale-down dies midway)."""
    env, cl = make_cluster(cp_fn_split_enabled=True, cp_rebalance_period=1e9,
                           persist_sandbox_state=True)   # wide teardown yields
    leader = preload(cl, ["f"], scaling_kw=LONG_SCALING)
    invs = [cl.invoke("f", exec_time=0.05) for _ in range(6)]
    env.run(until=env.now + 3.0)
    st = leader.functions["f"]
    assert st.ready_count >= 4
    assert all(not i.failed for i in invs)
    home = leader._fn_shard_id("f")
    members = (home, (home + 1) % 4, (home + 2) % 4)
    ev = env.process(leader._split_function("f", members), name="split")
    env.run_until_event(ev)
    # force a full scale-down and race a merge into the teardown window
    st.autoscaler.desired = lambda t, cur: 0
    env.process(leader._reconcile_function("f", st), name="global-reconcile")

    def delayed_merge(env):
        # lands inside the first victim's persisted teardown write
        yield env.timeout(0.5e-3)
        yield from leader._merge_function("f")

    env.process(delayed_merge(env), name="delayed-merge")
    env.run(until=env.now + 10.0)    # pre-fix: AttributeError escapes here
    assert st.slices is None
    assert cl.collector.fn_merges == 1
    assert st.creating == 0
    assert_ownership_consistent(leader)


def test_eviction_remove_rides_owning_slice_queue():
    """Regression: a dead worker's split-function replicas must queue their
    endpoint removals on the owning *slice's* flush queue (the documented
    exactly-once-per-subshard routing), not the home shard's."""
    env, cl = make_cluster(cp_fn_split_enabled=True, n_workers=8,
                           cp_rebalance_period=1e9)
    leader = preload(cl, ["f"])
    home = leader._fn_shard_id("f")
    other = (home + 1) % 4
    ev = env.process(leader._split_function("f", (home, other)), name="split")
    env.run_until_event(ev)
    st = leader.functions["f"]
    wid = next(w for w in cl.workers if w % 4 == other)
    sb = Sandbox(sandbox_id=7001, function_name="f", ip=(10, 0, 0, 9),
                 port=80, worker_id=wid)
    st.sandboxes[sb.sandbox_id] = sb
    st.slices[other].sandbox_ids.add(sb.sandbox_id)
    calls = []
    orig = leader._queue_endpoint_update

    def spy(op, fn, payload, drain=True, shard=None):
        calls.append((op, payload,
                      None if shard is None else shard.shard_id))
        return orig(op, fn, payload, drain=drain, shard=shard)

    leader._queue_endpoint_update = spy
    ev = env.process(
        leader._evict_worker(leader._worker_shard(wid), wid), name="evict")
    env.run_until_event(ev)
    assert ("remove", 7001, other) in calls, calls


def test_fn_split_max_shards_clamped_to_two():
    """Regression: a shard-set ceiling below 2 used to make the escalation
    select a dominant function every tick (suppressing whole moves for it)
    while never being able to split it — the knob is clamped instead."""
    env, cl = make_cluster(cp_fn_split_enabled=True,
                           cp_fn_split_max_shards=1)
    leader = cl.control_plane_leader()
    assert leader.fn_split_max_shards == 2
    preload(cl, ["hot"])
    drive_dominant(env, cl, until=13.0)
    env.run(until=12.0)
    st = leader.functions["hot"]
    assert st.slices is not None and len(st.slices) == 2


# -- deregistration -----------------------------------------------------------

def test_deregister_split_function_cleans_every_subshard():
    env, cl = make_cluster(cp_fn_split_enabled=True, n_workers=8,
                           cp_rebalance_period=1e9)
    leader = preload(cl, ["f"])
    home = leader._fn_shard_id("f")
    members = (home, (home + 1) % 4, (home + 2) % 4)
    ev = env.process(leader._split_function("f", members), name="split")
    env.run_until_event(ev)
    env.run(until=env.now + 1.0)
    assert "shardmap/f" in cl.store.peek_prefix("shardmap/")
    ev = env.process(leader.deregister_function("f"), name="dereg")
    env.run_until_event(ev)
    assert "f" not in leader.functions
    assert "f" not in leader.fn_shard_table
    assert all("f" not in s.functions for s in leader.shards)
    assert not cl.store.peek_prefix("shardmap/"), "override not tombstoned"
