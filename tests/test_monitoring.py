"""Metrics/event-log endpoint tests (paper §4 operations & monitoring)."""
from repro.core import Cluster, Function
from repro.core.monitoring import render_event_log, render_metrics
from repro.simcore import Environment


def test_metrics_exposition():
    env = Environment(seed=3)
    cl = Cluster(env, n_workers=4)
    cl.start()
    cl.register_sync(Function(name="f", image_url="i", port=80))
    cl.invoke("f", exec_time=0.01)
    env.run(until=5.0)
    text = render_metrics(cl)
    assert 'dirigent_invocations_total{status="ok"} 1' in text
    assert "dirigent_sandbox_creations_total 1" in text
    assert 'dirigent_function_ready_sandboxes{function="f"} 1' in text
    assert "dirigent_workers_alive 4" in text
    # persistence counter only reflects registration-time writes
    assert "dirigent_persistent_writes_total" in text


def test_event_log_contains_failover():
    env = Environment(seed=4)
    cl = Cluster(env, n_workers=4, enable_ha_sim=True)
    cl.start()
    cl.register_sync(Function(name="f", image_url="i", port=80))
    env.run(until=2.0)
    cl.fail_control_plane_leader()
    env.run(until=4.0)
    log = render_event_log(cl)
    assert "cp-failed" in log
    assert "leader-elected" in log
