"""Pluggable scheduling-policy tests (paper §4: Hermod, CH-RLU support)."""
from dataclasses import dataclass

import pytest

from repro.core import Cluster, Function, ScalingConfig
from repro.core.placement import Placer
from repro.core.policies import lb_ch_rlu, lb_least_loaded, place_hermod
from repro.simcore import Environment


@dataclass
class Ep:
    in_use: int = 0
    capacity: int = 4
    draining: bool = False

    @property
    def free(self):
        return self.capacity - self.in_use


def test_ch_rlu_warm_locality_and_bound():
    eps = {i: Ep() for i in range(4)}
    first = lb_ch_rlu(eps, "fnA")
    # repeated picks for the same function stick to the same endpoint...
    assert lb_ch_rlu(eps, "fnA") is first
    # ...until it exceeds the load bound, then the walk moves on
    first.in_use = 4
    nxt = lb_ch_rlu(eps, "fnA")
    assert nxt is not first and nxt.free > 0


def test_ch_rlu_full_ring_returns_none():
    eps = {i: Ep(in_use=4) for i in range(3)}
    assert lb_ch_rlu(eps, "fnA") is None


def test_least_loaded_picks_minimum():
    eps = {0: Ep(in_use=3), 1: Ep(in_use=1), 2: Ep(in_use=2)}
    assert lb_least_loaded(eps, "f") is eps[1]


def test_hermod_packs_busiest_fitting_node():
    p = Placer(policy="hermod_packing")
    for i in range(3):
        p.add_node(i, 1000, 1000)
    p.commit(1, 500, 500)        # node 1 is half full
    assert p.place(100, 100) == 1   # packs onto the busiest
    # fill node 1; next goes to the next-busiest
    p.commit(1, 400, 400)
    assert p.place(200, 200) != 1


def test_balanced_spreads_load():
    p = Placer(policy="balanced")
    for i in range(3):
        p.add_node(i, 1000, 1000)
    picks = [p.place(100, 100) for _ in range(3)]
    assert len(set(picks)) == 3      # spreads across nodes


def test_placer_node_readd_no_stale_scores():
    """Regression: removing a node then re-registering its id (node replaced
    with different capacity) must not resurrect index entries scored against
    the old incarnation."""
    a = Placer("balanced", use_index=True)
    b = Placer("balanced", use_index=False)
    for p in (a, b):
        p.add_node(1, 1000, 1000)
        p.add_node(2, 1000, 1000)
    assert a.place(100, 100) == b.place(100, 100)
    for p in (a, b):
        p.remove_node(2)
        p.add_node(2, 300, 300)      # same id, smaller node
    for _ in range(4):
        assert a.place(100, 100) == b.place(100, 100)


def test_partitioned_placer_shard_rotation_and_fallback():
    from repro.core.placement import make_placer
    p = make_placer("partitioned", n_shards=4)
    for i in range(8):
        p.add_node(i, 1000, 1000)
    picks = [p.place(100, 100) for _ in range(8)]
    assert None not in picks
    # round-robin cursor touches every shard
    assert {w % 4 for w in picks} == {0, 1, 2, 3}
    # fill shard 0 completely; placements fall through to other shards
    for w in (0, 4):
        while p.nodes[w].fits(100, 100):
            p.commit(w, 100, 100)
    for _ in range(8):
        w = p.place(100, 100)
        assert w is not None and w % 4 != 0


def test_cluster_runs_with_partitioned_placement():
    env = Environment(seed=9)
    cl = Cluster(env, n_workers=16, placement_policy="partitioned")
    cl.start()
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=ScalingConfig(stable_window=60,
                                                    scale_to_zero_grace=60)))
    invs = [cl.invoke("f", exec_time=0.5) for _ in range(6)]
    env.run(until=20.0)
    assert all(not i.failed for i in invs)
    assert cl.control_plane_leader().functions["f"].ready_count >= 1


def test_cluster_runs_with_alternate_policies():
    env = Environment(seed=5)
    cl = Cluster(env, n_workers=6, lb_policy="ch_rlu",
                 placement_policy="hermod_packing")
    cl.start()
    cl.register_sync(Function(name="f", image_url="i", port=80,
                              scaling=ScalingConfig(stable_window=60,
                                                    scale_to_zero_grace=60)))
    invs = [cl.invoke("f", exec_time=0.5) for _ in range(4)]
    env.run(until=20.0)
    assert all(not i.failed for i in invs)


def test_placer_pending_sweep_insertion_order_independent():
    """Regression for the ``sorted(self.pending)`` sweep in
    _ScoreIndex.pop_best (simlint: set-iteration): the placement sequence
    must not depend on the *history* that populated the pending set. Two
    placers with identical node state but opposite registration (and touch)
    orders must place identically — and match the brute-force reference."""
    fwd = Placer("balanced", use_index=True)
    rev = Placer("balanced", use_index=True)
    ref = Placer("balanced", use_index=False)
    ids = list(range(12))
    for wid in ids:
        fwd.add_node(wid, 1000, 1000)
        ref.add_node(wid, 1000, 1000)
    for wid in reversed(ids):        # different insertion history into pending
        rev.add_node(wid, 1000, 1000)
    picks = []
    for step in range(30):
        a, b, c = fwd.place(100, 100), rev.place(100, 100), ref.place(100, 100)
        assert a == b == c, f"diverged at step {step}: {a} {b} {c}"
        picks.append(a)
        if step == 14:
            # mid-stream churn re-dirties pending in opposite orders too
            for wid in ids[:6]:
                fwd.release(wid, 50, 50)
                ref.release(wid, 50, 50)
            for wid in reversed(ids[:6]):
                rev.release(wid, 50, 50)
    assert len(set(picks)) > 1       # the workload actually exercised spread
