"""Training substrate: loss descent, accumulation, compression, checkpoints."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.api import RunConfig, build_model
from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.train.compress import quantize_int8, dequantize_int8, roundtrip_tree
from repro.train.data import ZipfLMStream, random_tokens
from repro.train.optimizer import adamw_init, adamw_pspecs
from repro.train.train_step import make_train_step

CFG = get_config("smollm-360m").reduced(n_layers=2, d_model=64, n_heads=4,
                                        d_ff=128, vocab=256)


def _setup(run_kw=None):
    run = RunConfig(q_chunk=16, kv_chunk=16, **(run_kw or {}))
    model = build_model(CFG, run)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, lr=1e-3))
    return model, params, opt, step


def test_loss_decreases():
    model, params, opt, step = _setup()
    stream = ZipfLMStream(vocab=256, seq=32, batch=8, seed=3)
    losses = []
    for i in range(15):
        params, opt, m = step(params, opt, stream.batch_at(i),
                              jax.random.PRNGKey(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert not any(np.isnan(losses))


def test_grad_accum_equivalence():
    """accum=2 over a batch == single step over the same batch (same math
    modulo fp reordering)."""
    model1, params, opt, step1 = _setup()
    _, _, _, step2 = _setup({"grad_accum": 2})
    batch = random_tokens(0, 8, 32, 256)
    p1, _, m1 = step1(params, opt, batch, jax.random.PRNGKey(0))
    p2, _, m2 = step2(params, opt, batch, jax.random.PRNGKey(0))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-4, rtol=1e-3)


def test_int8_quantizer_unbiased():
    x = jax.random.normal(jax.random.PRNGKey(0), (4096,)) * 3.0
    keys = jax.random.split(jax.random.PRNGKey(1), 64)
    acc = jnp.zeros_like(x)
    for k in keys:
        q, s = quantize_int8(x, k)
        acc = acc + dequantize_int8(q, s)
    mean = acc / len(keys)
    scale = float(jnp.max(jnp.abs(x))) / 127.0
    # stochastic rounding: E[deq] == x (within sampling noise ~ scale/sqrt(n))
    assert float(jnp.abs(mean - x).max()) < scale * 1.2


def test_compressed_training_still_learns():
    model, params, opt, _ = _setup({"grad_compress": True})
    step = jax.jit(make_train_step(model, lr=1e-3))
    stream = ZipfLMStream(vocab=256, seq=32, batch=8, seed=5)
    losses = []
    for i in range(15):
        params, opt, m = step(params, opt, stream.batch_at(i),
                              jax.random.PRNGKey(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_checkpoint_resume_bitexact(tmp_path):
    """save -> restore -> continue == continuous run (fault tolerance)."""
    model, params, opt, step = _setup()
    stream = ZipfLMStream(vocab=256, seq=32, batch=8, seed=7)
    for i in range(4):
        params, opt, m = step(params, opt, stream.batch_at(i),
                              jax.random.PRNGKey(i))
    save_checkpoint(str(tmp_path), 4, {"params": params, "opt": opt})
    # continue the original
    p_cont, o_cont = params, opt
    for i in range(4, 8):
        p_cont, o_cont, _ = step(p_cont, o_cont, stream.batch_at(i),
                                 jax.random.PRNGKey(i))
    # restart from the checkpoint
    (restored, step_n) = restore_checkpoint(str(tmp_path), None,
                                            {"params": params, "opt": opt})
    assert step_n == 4
    p_re, o_re = restored["params"], restored["opt"]
    for i in range(4, 8):
        p_re, o_re, _ = step(p_re, o_re, stream.batch_at(i),
                             jax.random.PRNGKey(i))
    for a, b in zip(jax.tree.leaves(p_cont), jax.tree.leaves(p_re)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_latest(tmp_path):
    model, params, opt, step = _setup()
    t = save_checkpoint(str(tmp_path), 1, {"p": params}, async_save=True)
    t.join()
    save_checkpoint(str(tmp_path), 5, {"p": params})
    assert latest_step(str(tmp_path)) == 5


def test_elastic_restore_resharding(tmp_path):
    """Restore a checkpoint onto a different mesh (shrunk data axis) — the
    elastic-rescale path after node loss."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    model, params, opt, step = _setup()
    save_checkpoint(str(tmp_path), 2, {"params": params})
    from repro.models.sharding import compat_make_mesh
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    shardings = jax.tree.map(
        lambda sp: NamedSharding(mesh, sp),
        model.param_pspecs(),
        is_leaf=lambda x: isinstance(x, P))
    (restored, _) = restore_checkpoint(str(tmp_path), 2, {"params": params},
                                       shardings={"params": shardings})
    for a, b in zip(jax.tree.leaves(restored["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_zero1_pspecs_shard_moments():
    from jax.sharding import PartitionSpec as P
    model, params, _, _ = _setup({"use_zero1": True})
    specs = model.param_specs()
    ps = adamw_pspecs(model.param_pspecs(), specs, use_zero1=True,
                      dax=("data",))
    flat = jax.tree.leaves(ps.mu, is_leaf=lambda x: isinstance(x, P))
    # at least the large moment tensors picked up a data-axis shard
    assert any("data" in str(p) for p in flat)


def test_data_stream_determinism():
    s1 = ZipfLMStream(vocab=128, seq=16, batch=4, seed=9)
    s2 = ZipfLMStream(vocab=128, seq=16, batch=4, seed=9)
    b1, b2 = s1.batch_at(17), s2.batch_at(17)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
