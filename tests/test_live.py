"""Live execution mode tests: shared executable cache, batcher-under-churn
semantics, LiveBackend hooks, and the DES invoke path with real payloads."""
import pytest

from repro.configs import get_config
from repro.core import Cluster
from repro.core.abstractions import Sandbox
from repro.core.monitoring import render_metrics
from repro.core.request import LiveRequest
from repro.live import LiveBackend, LiveFunctionSpec
from repro.serving.engine import ContinuousBatcher, Replica
from repro.serving.exec_cache import ExecutableCache
from repro.simcore import Environment

TINY = get_config("smollm-360m").reduced(
    n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab=128)


def _sandbox(sid: int, fn: str = "lf0") -> Sandbox:
    return Sandbox(sandbox_id=sid, function_name=fn,
                   ip=(10, 0, 0, 1), port=80, worker_id=0)


def _backend(max_slots: int = 4) -> LiveBackend:
    spec = LiveFunctionSpec(cfg=TINY, mode="process", max_seq=64,
                            max_slots=max_slots, default_max_new=4)
    return LiveBackend(default_spec=spec, exec_cache=ExecutableCache())


# -- shared executable cache (satellite: cold-start double-compile) -----------

def test_second_replica_compiles_zero_new_executables():
    cache = ExecutableCache()
    r1 = Replica(TINY, max_seq=64, exec_cache=cache)
    out1 = r1.generate([1, 2, 3], max_new_tokens=4)
    compiled_after_first = cache.compiled_executables()
    assert compiled_after_first >= 1          # first replica traced decode
    assert cache.misses == 1
    r2 = Replica(TINY, max_seq=64, exec_cache=cache)
    out2 = r2.generate([1, 2, 3], max_new_tokens=4)
    # the regression this cache exists to prevent: a second replica of the
    # same (cfg, run_cfg) must reuse the traced executables, not recompile
    assert cache.compiled_executables() == compiled_after_first
    assert cache.hits >= 1
    assert r2._decode is r1._decode and r2._prefill is r1._prefill
    assert out1 == out2                       # same params seed, same model


def test_replicas_share_executables_not_state():
    cache = ExecutableCache()
    r1 = Replica(TINY, max_seq=64, rng_seed=0, exec_cache=cache)
    r2 = Replica(TINY, max_seq=64, rng_seed=1, exec_cache=cache)
    assert r1.model is r2.model               # stateless: only (cfg, run)
    assert r1.params is not r2.params         # per-replica state


def test_cache_capacity_evicts_lru():
    cache = ExecutableCache(capacity=1)
    cache.get(TINY)
    cache.get(TINY.reduced(n_layers=1, d_model=32, n_heads=2,
                           d_ff=64, vocab=64))
    assert len(cache) == 1 and cache.evictions == 1


def test_warm_traces_shape_once():
    from repro.configs.base import ShapeSpec
    cache = ExecutableCache()
    shape = ShapeSpec("live", 64, 2, "decode")
    dt1 = cache.warm(TINY, shape)
    dt2 = cache.warm(TINY, shape)
    assert dt1 > 0.0 and dt2 == 0.0


# -- ContinuousBatcher under churn (satellite 3) ------------------------------

@pytest.fixture(scope="module")
def shared_replica():
    return Replica(TINY, max_seq=64, exec_cache=ExecutableCache())


def test_slot_admission_mid_flight_under_churn(shared_replica):
    """Admit into slots freed by finished requests while others are still
    decoding; every generation must match its solo run."""
    cb = ContinuousBatcher(shared_replica, max_slots=2)
    outs = {}
    prompts = {0: [1, 2, 3], 1: [4, 5], 2: [6, 7, 8], 3: [9]}
    rids = {cb.add_request(prompts[0], max_new=6): 0,
            cb.add_request(prompts[1], max_new=3): 1}
    pending = [2, 3]
    for _ in range(200):
        done = cb.step()
        for rid in done:
            outs[rids[rid]] = cb.finished[rid]
        # churn: refill freed slots mid-flight
        while pending and cb.free_slots:
            k = pending.pop(0)
            rids[cb.add_request(prompts[k],
                                max_new=6 if k == 2 else 2)] = k
        if len(outs) == 4:
            break
    assert len(outs) == 4
    solo = {k: shared_replica.generate(
        p, max_new_tokens={0: 6, 1: 3, 2: 6, 3: 2}[k])
        for k, p in prompts.items()}
    assert outs == solo


def test_per_slot_cache_length_isolation(shared_replica):
    """Slots advance their cache lengths independently: a long-prompt slot
    must not bleed position state into a short-prompt neighbour."""
    cb = ContinuousBatcher(shared_replica, max_slots=3)
    long_rid = cb.add_request([1, 2, 3, 4, 5, 6, 7, 8], max_new=2)
    for _ in range(3):
        cb.step()
    short_rid = cb.add_request([9], max_new=2)
    lens = {s.request_id: s.length for s in cb.slots if s.active}
    assert lens[long_rid] > lens[short_rid] == 0
    cb.run_until_done()
    assert cb.finished[long_rid] == shared_replica.generate(
        [1, 2, 3, 4, 5, 6, 7, 8], max_new_tokens=2)
    assert cb.finished[short_rid] == shared_replica.generate(
        [9], max_new_tokens=2)


def test_teardown_drain_finishes_in_slot_requests():
    """Graceful teardown (kill_sandbox path) drains: requests that were in
    slots still yield their tokens — the wall-side mirror of the DES
    teardown_drain_grace."""
    lb = _backend()
    lb.create_hook(_sandbox(1))
    t1 = lb.admit(1, LiveRequest(prompt=[1, 2], max_new_tokens=3))
    t2 = lb.admit(1, LiveRequest(prompt=[3], max_new_tokens=2))
    lb.teardown_hook(1, True)
    assert lb.replicas_live == 0
    for t in (t1, t2):
        req = lb.collect(t)
        assert not req.failed and len(req.tokens) > 0


def test_teardown_fail_fails_in_slot_requests():
    """Node-death teardown (fail_node path) aborts: in-slot requests fail
    with a reason instead of silently hanging."""
    lb = _backend()
    lb.create_hook(_sandbox(1))
    t1 = lb.admit(1, LiveRequest(prompt=[1, 2], max_new_tokens=3))
    lb.teardown_hook(1, False)
    req = lb.collect(t1)
    assert req.failed and "fail" in req.failure_reason
    assert req.tokens is None


def test_batcher_abort_discards_partials(shared_replica):
    cb = ContinuousBatcher(shared_replica, max_slots=2)
    rid = cb.add_request([1, 2, 3], max_new=8)
    for _ in range(5):
        cb.step()
    killed = cb.abort()
    assert killed == [rid]
    assert rid not in cb.finished
    assert all(not s.active for s in cb.slots)


# -- worker hooks (satellite 1: symmetric reclaim) ----------------------------

def test_worker_kill_sandbox_calls_teardown_hook():
    from repro.core.costmodel import DEFAULT_COSTS
    from repro.core.abstractions import WorkerNodeInfo
    from repro.core.worker import WorkerDaemon

    env = Environment(seed=1)
    calls = []
    w = WorkerDaemon(env, WorkerNodeInfo(0, "w0", (10, 0, 0, 1), 9000),
                     DEFAULT_COSTS.dirigent,
                     teardown_hook=lambda sid, drain: calls.append(
                         (sid, drain)))
    sb = _sandbox(7)
    env.process(w.create_sandbox(sb), name="create")
    env.run(until=5.0)
    assert sb.sandbox_id in w.sandboxes
    env.process(w.kill_sandbox(7), name="kill")
    env.run(until=10.0)
    assert calls == [(7, True)]               # graceful: drain semantics


def test_worker_fail_node_calls_teardown_hook_no_drain():
    from repro.core.costmodel import DEFAULT_COSTS
    from repro.core.abstractions import WorkerNodeInfo
    from repro.core.worker import WorkerDaemon

    env = Environment(seed=1)
    calls = []
    w = WorkerDaemon(env, WorkerNodeInfo(0, "w0", (10, 0, 0, 1), 9000),
                     DEFAULT_COSTS.dirigent,
                     teardown_hook=lambda sid, drain: calls.append(
                         (sid, drain)))
    for sid in (1, 2):
        env.process(w.create_sandbox(_sandbox(sid)), name=f"c{sid}")
    env.run(until=5.0)
    w.fail_node()
    assert sorted(calls) == [(1, False), (2, False)]
    assert not w.sandboxes


# -- end-to-end live invoke path ----------------------------------------------

def _live_cluster(env, lb, n_workers=4):
    cl = Cluster(env, n_workers=n_workers, runtime="firecracker",
                 live_backend=lb, sandbox_concurrency=4)
    cl.start()
    leader = cl.control_plane_leader()
    from repro.core import Function, ScalingConfig
    fn = Function(name="lf0", image_url="img://t", port=80,
                  scaling=ScalingConfig(stable_window=1.0, panic_window=1.0,
                                        scale_to_zero_grace=0.2))
    leader.install_function(fn)
    for dp in cl.data_planes:
        dp.sync_functions(["lf0"])
    return cl


def test_live_invoke_end_to_end_with_batching():
    env = Environment(seed=3)
    lb = _backend()
    cl = _live_cluster(env, lb)
    invs = []

    def driver(env):
        for i in range(5):
            invs.append(cl.invoke("lf0", 0.01, request=LiveRequest(
                prompt=[1, 2, 3], max_new_tokens=4)))
            yield env.timeout(0.001)

    env.process(driver(env), name="driver")
    env.run(until=30.0)
    done = [i for i in invs if i.t_done > 0 and not i.failed]
    assert len(done) == 5
    # every completed invocation executed a real payload
    assert all(i.request.tokens is not None and len(i.request.tokens) == 4
               for i in done)
    # identical requests to one replica produce identical tokens
    assert len({tuple(i.request.tokens) for i in done}) == 1
    # sim-concurrent requests shared decode steps in the batcher
    assert lb.batched_invokes > 0
    # wall time was billed to the sim clock: exec span covers payload wall
    assert all(i.t_done > i.t_exec_start for i in done)
    # creations were warm after the first (shared executable cache)
    colds = [r["cold"] for r in lb.start_log]
    assert colds.count(True) == 1


def test_live_metrics_rendered():
    env = Environment(seed=4)
    lb = _backend()
    cl = _live_cluster(env, lb)

    def driver(env):
        cl.invoke("lf0", 0.01,
                  request=LiveRequest(prompt=[5], max_new_tokens=2))
        yield env.timeout(0.0)

    env.process(driver(env), name="driver")
    env.run(until=10.0)
    m = render_metrics(cl)
    assert "dirigent_live_replicas" in m
    assert "dirigent_live_exec_cache_hits" in m
    assert "dirigent_live_exec_cache_misses" in m
    assert "dirigent_live_invoke_seconds" in m
    assert "dirigent_live_tokens_total" in m


def test_des_only_cluster_renders_no_live_metrics():
    env = Environment(seed=5)
    cl = Cluster(env, n_workers=2)
    cl.start()
    env.run(until=1.0)
    assert "dirigent_live_" not in render_metrics(cl)


def test_scale_to_zero_reclaims_live_replicas():
    env = Environment(seed=6)
    lb = _backend()
    cl = _live_cluster(env, lb)

    def driver(env):
        cl.invoke("lf0", 0.01,
                  request=LiveRequest(prompt=[1], max_new_tokens=2))
        yield env.timeout(0.0)

    env.process(driver(env), name="driver")
    env.run(until=60.0)                       # past scale-to-zero grace
    assert lb.replicas_live == 0              # teardown_hook reclaimed
    assert lb.teardowns >= 1


# -- container mode (subprocess worker; slower, one spawn) --------------------

def test_container_sandbox_roundtrip(tmp_path):
    spec = LiveFunctionSpec(cfg=TINY, mode="container", max_seq=64,
                            max_slots=2, default_max_new=3)
    lb = LiveBackend(default_spec=spec,
                     compile_cache_dir=str(tmp_path / "xla"))
    lb.create_hook(_sandbox(1))
    try:
        assert lb.start_log[0]["mode"] == "container"
        t = lb.admit(1, LiveRequest(prompt=[1, 2], max_new_tokens=3))
        req = lb.collect(t)
        assert not req.failed and len(req.tokens) == 3
    finally:
        lb.close()
    assert lb.replicas_live == 0
