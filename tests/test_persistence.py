"""Group commit, checkpointed recovery, and FileStore crash semantics.

The scale features this file covers are all default-off; the event-budget
pins in test_event_budget.py guarantee the off paths stay bit-identical,
while the tests here pin the ON semantics: batch absorption, stall
amplification across a batch, determinism, boot equivalence (same final
store state and registration order as the serialized path), checkpoint +
delta recovery, and the FileStore torn-tail / compaction behaviour the
SimStore checkpoints mirror.
"""
import os

from repro.core import Cluster, Function, ScalingConfig
from repro.core.persistence import (FileStore, SimStore, decode_records,
                                    encode_records)
from repro.simcore import Environment


# -- SimStore group commit ----------------------------------------------------

def make_store(env, **kw):
    kw.setdefault("fsync_latency", 1e-3)
    kw.setdefault("replication_latency", 0.5e-3)
    kw.setdefault("read_latency", 0.2e-3)
    kw.setdefault("fsync_sigma", 0.0)     # deterministic latency unless a
    kw.setdefault("stall_prob", 0.0)      # test opts into stalls
    return SimStore(env, **kw)


def staggered_writes(env, store, done_at):
    """One leading write, three absorbed behind its in-flight fsync, one
    straggler after everything settled."""
    def writer(key, delay):
        yield env.timeout(delay)
        yield from store.write(key, b"v-" + key.encode())
        done_at[key] = env.now
    for key, delay in [("a", 0.0), ("b", 1e-4), ("c", 1.2e-4),
                       ("d", 1.4e-4), ("e", 0.1)]:
        env.process(writer(key, delay), name=f"w-{key}")


def test_group_commit_absorbs_queued_writers():
    env = Environment(seed=1)
    store = make_store(env, group_commit=True)
    done_at = {}
    staggered_writes(env, store, done_at)
    env.run(until=1.0)
    # a commits alone; b/c/d queued behind a's in-flight fsync form ONE
    # batch; e arrives after the committer retired and commits alone
    assert store.group_commits == 3
    assert store.group_commit_writes == 5
    assert done_at["b"] == done_at["c"] == done_at["d"]
    assert done_at["a"] < done_at["b"] < done_at["e"]
    assert store.write_count == 5
    assert store.peek("c") == b"v-c"


def test_group_commit_stall_holds_whole_batch():
    """A compaction stall on ANY batch member delays every member: the batch
    settles at the slowest draw, so absorbed writers share the p99 surge."""
    env = Environment(seed=1)
    store = make_store(env, group_commit=True, stall_prob=1.0, stall=0.120)
    done_at = {}
    staggered_writes(env, store, done_at)
    env.run(until=5.0)
    # every member of the b/c/d batch finishes at the same stalled instant,
    # >= stall * 0.5 after they were enqueued
    assert done_at["b"] == done_at["c"] == done_at["d"]
    assert done_at["b"] - 1e-4 >= 0.120 * 0.5


def test_group_commit_two_run_determinism():
    def run():
        env = Environment(seed=7)
        store = make_store(env, group_commit=True, fsync_sigma=0.4,
                           stall_prob=0.01)
        done_at = {}
        staggered_writes(env, store, done_at)
        env.run(until=5.0)
        return done_at, dict(store.data), env.events_processed
    assert run() == run()


def test_write_many_off_mode_matches_serial_writes():
    """With group commit off, write_many degrades to the per-record
    serialized path bit-identically (same draws, same completion instant)."""
    items = [(f"k{i}", f"v{i}".encode()) for i in range(6)]

    def run(bulk):
        env = Environment(seed=3)
        store = make_store(env, group_commit=False, fsync_sigma=0.4)

        def driver():
            if bulk:
                yield from store.write_many(items)
            else:
                for k, v in items:
                    yield from store.write(k, v)
        env.process(driver(), name="driver")
        env.run(until=5.0)
        return env.now, dict(store.data), store.write_count, \
            env.events_processed

    assert run(bulk=True) == run(bulk=False)


def test_write_many_commits_in_max_batch_chunks():
    env = Environment(seed=4)
    store = make_store(env, group_commit=True, max_batch=4)
    items = [(f"k{i}", b"x") for i in range(10)]
    env.process(store.write_many(items), name="bulk")
    env.run(until=1.0)
    assert store.group_commits == 3          # 4 + 4 + 2
    assert store.last_batch_size == 2
    assert list(store.data) == [k for k, _ in items]   # insertion order kept
    assert store.write_count == 10


# -- boot-path equivalence ----------------------------------------------------

def boot_cluster(group_commit, n_workers=48, seed=11):
    env = Environment(seed=seed)
    cl = Cluster(env, n_workers=n_workers, cp_shards=4,
                 persist_group_commit=group_commit)
    cl.start()
    return env, cl


def test_boot_equivalence_and_speedup():
    """Group-commit boot must land the exact same worker log (records AND
    insertion order) and CP state as the serialized boot — just faster."""
    env_off, cl_off = boot_cluster(group_commit=False)
    env_on, cl_on = boot_cluster(group_commit=True)
    assert cl_on.store.peek_prefix("worker/") == \
        cl_off.store.peek_prefix("worker/")
    assert list(cl_on.store.data) == list(cl_off.store.data)
    leader_on, leader_off = (cl_on.control_plane_leader(),
                             cl_off.control_plane_leader())
    assert list(leader_on.workers) == list(leader_off.workers)
    assert leader_on.placer.nodes.keys() == leader_off.placer.nodes.keys()
    assert cl_on.store.write_count == cl_off.store.write_count
    assert cl_on.store.group_commits > 0
    # the point of the feature: boot is O(batches), not O(n_workers) fsyncs
    assert env_on.now < env_off.now / 5


def test_boot_equivalence_post_boot_workload():
    """Post-boot behaviour is equivalent too: the same workload started at
    boot-complete produces the same creations and completions."""
    stats = []
    for gc in (False, True):
        env, cl = boot_cluster(group_commit=gc, n_workers=24)
        cl.register_sync(Function(name="f", image_url="i", port=80))
        t0 = env.now
        for _ in range(8):
            cl.invoke("f", exec_time=0.02)
        env.run(until=t0 + 5.0)
        stats.append((len(cl.collector.completed),
                      len(cl.collector.failed),
                      cl.collector.sandbox_creations))
    assert stats[0] == stats[1]


def test_deposed_leader_write_lands_mid_batch():
    """A write enqueued under a leader that dies before the batch commits
    still lands (the store is the replicated quorum, not the leader) and the
    new leader recovers it."""
    env = Environment(seed=5)
    cl = Cluster(env, n_workers=8, enable_ha_sim=True,
                 persist_group_commit=True)
    cl.start()
    env.run(until=2.0)
    leader = cl.control_plane_leader()
    old_id = leader.cp_id
    env.process(leader.register_function(
        Function(name="late", image_url="i", port=80)), name="late-reg")
    # grpc hop done, persist write enqueued, group-commit fsync in flight
    env.run(until=env.now + 0.8e-3)
    assert cl.store._committing and cl.store.peek("function/late") is None
    cl.fail_control_plane_leader()
    env.run(until=env.now + 2.0)
    new_leader = cl.control_plane_leader()
    assert new_leader is not None and new_leader.cp_id != old_id
    assert cl.store.peek("function/late") is not None
    assert "late" in new_leader.functions


# -- SimStore checkpoints -----------------------------------------------------

def test_checkpoint_roundtrip_with_delta_and_tombstone():
    env = Environment(seed=6)
    store = make_store(env, checkpoint_enabled=True)

    def driver():
        yield from store.write("function/a", b"A")
        yield from store.write("worker/1", b"W1")
        yield from store.write("worker/2", b"W2")
        yield from store.write_checkpoint()
        # post-checkpoint delta: one update, one new key, one tombstone
        yield from store.write("worker/1", b"W1b")
        yield from store.write("function/b", b"B")
        yield from store.write("worker/2", None)
        got = yield from store.read_checkpoint()
        snap, delta = got
        assert snap == {"function/a": b"A", "worker/1": b"W1",
                        "worker/2": b"W2"}
        assert delta == {"worker/1": b"W1b", "function/b": b"B",
                         "worker/2": None}
    env.process(driver(), name="driver")
    env.run(until=5.0)
    assert store.checkpoint_epoch == 1
    assert store.checkpoint_at is not None
    # only the latest checkpoint record is retained
    assert [k for k in store.data if k.startswith("checkpoint/")] == \
        ["checkpoint/1"]


def test_checkpoint_recovery_matches_full_replay():
    """A leader recovering from checkpoint + delta must end with the same
    functions, workers and shard table as one replaying the full log."""
    recovered = []
    for ckpt in (False, True):
        env = Environment(seed=9)
        cl = Cluster(env, n_workers=16, cp_shards=4, enable_ha_sim=True,
                     cp_checkpoint_enabled=ckpt, cp_checkpoint_period=1.0)
        cl.start()
        for i in range(4):
            cl.register_sync(Function(name=f"f{i}", image_url="i", port=80))
        env.run(until=3.0)   # >= one checkpoint period when enabled
        if ckpt:
            assert cl.store.checkpoint_epoch >= 1
        # post-checkpoint delta: a new function and a deregistration
        cl.register_sync(Function(name="f-late", image_url="i", port=80))
        leader = cl.control_plane_leader()
        env.process(leader.deregister_function("f0"), name="dereg")
        env.run(until=4.0)
        cl.fail_control_plane_leader()
        env.run(until=8.0)
        leader = cl.control_plane_leader()
        assert cl.collector.first_event_at("cp-recovered", after=4.0)
        recovered.append((sorted(leader.functions),
                          sorted(leader.workers),
                          dict(sorted(leader.fn_shard_table.items()))))
    assert recovered[0] == recovered[1]
    assert "f-late" in recovered[1][0] and "f0" not in recovered[1][0]


def test_checkpoint_loop_runs_off_critical_path():
    env = Environment(seed=10)
    cl = Cluster(env, n_workers=8, enable_ha_sim=True,
                 cp_checkpoint_enabled=True, cp_checkpoint_period=0.5)
    cl.start()
    env.run(until=3.0)
    epochs = cl.collector.event_times("cp-checkpoint")
    assert len(epochs) >= 3
    assert cl.store.checkpoint_epoch == len(epochs)
    # the checkpointer is leader-bound: a deposed leader stops writing them
    cl.fail_control_plane_leader()
    env.run(until=6.0)
    assert cl.collector.event_times("cp-checkpoint", after=3.0)


# -- FileStore crash recovery + compaction ------------------------------------

def test_filestore_appends_survive_torn_tail_recovery(tmp_path):
    """Regression: the replayer used to leave crash garbage in place and
    reopen in append mode BEHIND it, so every post-recovery write sat after
    the torn record and was silently lost on the next open. The tail must be
    truncated to the last valid record before appending."""
    path = os.fspath(tmp_path / "store.log")
    st = FileStore(path)
    st.write("k1", b"v1")
    st.write("k2", b"v2")
    st.close()
    with open(path, "ab") as fh:
        fh.write(b"\x07\x00garbage")          # torn/corrupt tail
    st2 = FileStore(path)
    assert st2.data == {"k1": b"v1", "k2": b"v2"}
    st2.write("k3", b"v3")                     # append after crash recovery
    st2.close()
    st3 = FileStore(path)                      # second recovery must see k3
    assert st3.data == {"k1": b"v1", "k2": b"v2", "k3": b"v3"}
    st3.close()


def test_filestore_compaction_threshold(tmp_path):
    path = os.fspath(tmp_path / "store.log")
    st = FileStore(path, compact_threshold=1024)
    for i in range(200):
        st.write("hot", f"v{i}".encode() * 4)
    assert st.compactions >= 1
    assert os.path.getsize(path) < 1024
    st.write("cold", b"c")
    st.close()
    st2 = FileStore(path)
    assert st2.data == {"hot": b"v199" * 4, "cold": b"c"}
    st2.close()


def test_filestore_compact_on_open(tmp_path):
    path = os.fspath(tmp_path / "store.log")
    st = FileStore(path)
    for i in range(50):
        st.write("k", f"v{i}".encode())
    st.write("gone", b"x")
    st.write("gone", None)                     # tombstone
    st.close()
    big = os.path.getsize(path)
    st2 = FileStore(path, compact_on_open=True)
    assert st2.compactions == 1
    assert st2.data == {"k": b"v49"}
    st2.close()
    assert os.path.getsize(path) < big
    st3 = FileStore(path)                      # compacted log replays clean
    assert st3.data == {"k": b"v49"}
    st3.close()


def test_simstore_checkpoint_payload_replays_as_filestore_log(tmp_path):
    """SimStore checkpoints and the FileStore log share one record framing:
    a checkpoint payload dropped into a file IS a valid compacted log."""
    env = Environment(seed=12)
    store = make_store(env, checkpoint_enabled=True)

    def driver():
        yield from store.write("worker/1", b"W1")
        yield from store.write("function/a", b"A")
        yield from store.write("worker/2", None)   # tombstone never snapshotted
        yield from store.write_checkpoint()
    env.process(driver(), name="driver")
    env.run(until=5.0)
    payload = store.peek("checkpoint/1")
    path = os.fspath(tmp_path / "ckpt.log")
    with open(path, "wb") as fh:
        fh.write(payload)
    st = FileStore(path)
    assert st.data == {"worker/1": b"W1", "function/a": b"A"}
    assert st.data == decode_records(encode_records(st.data))
    st.close()
